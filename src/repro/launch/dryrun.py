import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, and extract the roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch yi-34b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi            # all

Outputs one JSON per cell under benchmarks/results/dryrun/, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import re
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp

from .mesh import make_production_mesh, use_mesh
from .steps import build_step
from ..configs import get_config, shape_names, ARCH_IDS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")

# v5e hardware constants (DESIGN/EXPERIMENTS roofline)
PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
ICI_BW = 50e9               # B/s effective per-chip ICI (per link figure)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip ICI traffic estimate per collective family, from HLO text.

    Conventions: all-reduce ~ 2x result bytes (ring); all-gather /
    all-to-all / collective-permute ~ result bytes; reduce-scatter ~
    result bytes x (group-1) (operand-sized ring pass).
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        rb = _shape_bytes(m.group(1))
        op = m.group(2)
        if op == "all-reduce":
            traffic = 2 * rb
        elif op == "reduce-scatter":
            g = 2
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len(gm.group(1).split(","))
            traffic = rb * max(g - 1, 1)
        else:
            traffic = rb
        out[op] += traffic
    out["total"] = sum(out.values())
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _compile_bundle(bundle, mesh):
    if bundle.in_shardings is not None:
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    else:
        fn = bundle.fn  # already jit-wrapped (coregraph engine)
    with use_mesh(mesh):
        lowered = fn.lower(*bundle.args)
        compiled = lowered.compile()
    return compiled


def _metrics(compiled) -> dict:
    cost = _cost_dict(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(compiled.as_text()),
    }


def _shard_frac(sharding) -> int:
    """How many ways a NamedSharding splits its array."""
    f = 1
    spec = sharding.spec
    for entry in spec:
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        for nm in names:
            f *= dict(sharding.mesh.shape)[nm]
    return f


def _args_bytes_per_chip(bundle) -> float:
    """Per-chip bytes of all step arguments (params, opt state, caches, batch)."""
    total = 0.0
    if bundle.in_shardings is None:
        return 0.0
    for aval_tree, sh_tree in zip(bundle.args, bundle.in_shardings):
        avals = jax.tree.leaves(aval_tree)
        shs = jax.tree.leaves(
            sh_tree, is_leaf=lambda x: hasattr(x, "spec"))
        for a, s in zip(avals, shs):
            total += np.prod(a.shape) * jnp.dtype(a.dtype).itemsize / _shard_frac(s)
    return total


def _memory_model(arch, shape, mesh, bundle, chips) -> dict:
    """Analytic per-chip HBM model (the TPU 'does it fit' check; the CPU
    backend's temp_bytes lacks TPU fusion/remat and wildly overstates)."""
    from ..configs import get_config

    args = _args_bytes_per_chip(bundle)
    cfg = get_config(arch)
    act = 0.0
    grads = 0.0
    if cfg.kind == "coregraph":
        # replicated node state (core in + combined out) + per-chip edge
        # shard (dst/rows/mask) + per-chip owned-slot state (ids/mask/
        # lsegptr/cnt/active); the scatter id map is gathered on-mesh per
        # chunk, not shipped replicated (resident._shard_chunk_fn)
        args = 2 * cfg.n * 4 + cfg.m_directed / chips * 9 \
            + cfg.n / chips * 14
        act = cfg.m_directed / chips * 8  # gathered nbr cores + index arrays
    elif bundle.name == "train_step" and cfg.kind == "lm":
        accum = bundle.static.get("accum", 1)
        from ..configs import SHAPES_BY_KIND
        sh = SHAPES_BY_KIND["lm"][shape]
        ba_shards = chips // dict(mesh.shape).get("model", 1)
        tok_chip = sh["global_batch"] * sh["seq_len"] / ba_shards / accum
        # remat saves one (tokens, d_model) bf16 per layer + ~8x working set
        act = tok_chip * cfg.d_model * 2 * (cfg.n_layers + 8)
        grads = bundle.num_params * 4 / chips  # fp32 grad accum, fully sharded
    elif bundle.name == "train_step":
        act = args * 4  # GNN/recsys: a few activation-sized buffers
        grads = bundle.num_params * 4  # replicated small models
    else:
        act = args * 0.25
    total = args + act + grads
    return {
        "args_bytes_per_chip": args,
        "activation_bytes_per_chip": act,
        "grad_bytes_per_chip": grads,
        "total_bytes_per_chip": total,
        "fits_16GB_hbm": bool(total < 16e9 * 0.92),
    }


def run_cell(arch: str, shape: str, mesh, mesh_name: str, chips: int) -> dict:
    from ..configs import get_config

    t0 = time.time()
    bundle = build_step(arch, shape, mesh)
    compiled = _compile_bundle(bundle, mesh)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }

    # --- roofline metrics -------------------------------------------------
    # HloCostAnalysis counts a `while` body once, so scanned layer stacks
    # undercount by ~L.  For LM cells we therefore compile two *unrolled*
    # shallow variants (depth d0, d0+1) and extrapolate linearly in depth;
    # other families have no layer scans (python loops) and are exact.
    cfg = get_config(arch)
    extrapolated = False
    if cfg.kind == "lm":
        kd = cfg.moe.first_k_dense if cfg.moe is not None else 0
        d0 = kd + 1
        os.environ["REPRO_UNROLL_SCANS"] = "1"
        # grad accumulation is metric-neutral (same total flops/bytes/
        # collectives); accum=1 keeps the unrolled metric HLO small
        os.environ["REPRO_ACCUM_TOKENS"] = str(10**9)
        try:
            b0 = build_step(arch, shape, mesh, depth_override=d0)
            m0 = _metrics(_compile_bundle(b0, mesh))
            b1 = build_step(arch, shape, mesh, depth_override=d0 + 1)
            m1 = _metrics(_compile_bundle(b1, mesh))
        finally:
            del os.environ["REPRO_UNROLL_SCANS"]
            del os.environ["REPRO_ACCUM_TOKENS"]
        L = cfg.n_layers

        def extrap(a, b):
            # linear in depth; if the partitioner's strategy flips between
            # depths (negative delta), fall back to the mean per-layer rate
            delta = b - a
            if delta <= 0:
                delta = b / (d0 + 1)
            return a + (L - d0) * delta

        flops = extrap(m0["flops"], m1["flops"])
        bytes_accessed = extrap(m0["bytes"], m1["bytes"])
        coll = {k: extrap(m0["coll"][k], m1["coll"][k]) for k in m0["coll"]}
        extrapolated = True
    elif arch.startswith("semicore"):
        # per-superstep terms: unroll the probe loop, body counted once
        os.environ["REPRO_UNROLL_SCANS"] = "1"
        try:
            m0 = _metrics(_compile_bundle(build_step(arch, shape, mesh), mesh))
        finally:
            del os.environ["REPRO_UNROLL_SCANS"]
        flops, bytes_accessed, coll = m0["flops"], m0["bytes"], m0["coll"]
    else:
        m0 = _metrics(compiled)
        flops, bytes_accessed, coll = m0["flops"], m0["bytes"], m0["coll"]

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll["total"] / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]

    return {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "step": bundle.name, "num_params": bundle.num_params,
        "ok": True, "extrapolated_depth_metrics": extrapolated,
        "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "memory_model": _memory_model(arch, shape, mesh, bundle, chips),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": coll,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
        },
    }


def all_cells():
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shape_names(cfg):
            cells.append((arch, shape))
    # the paper's own workload (extra beyond the 40 assigned cells)
    cells.append(("semicore-webscale", "decompose"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False), 256))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True), 512))

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    failures = 0
    for mesh_name, mesh, chips in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                rec = run_cell(arch, shape, mesh, mesh_name, chips)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[FAIL] {tag}: {rec['error']}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok"):
                r = rec["roofline"]
                print(f"[ ok ] {tag} compile={rec['compile_s']:.1f}s "
                      f"flops/chip={rec['hlo_flops_per_chip']:.3g} "
                      f"dom={r['dominant']}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
