from .checkpoint import save, restore, latest_step, CheckpointManager
from .trainer import TrainLoop, make_source

__all__ = ["save", "restore", "latest_step", "CheckpointManager",
           "TrainLoop", "make_source"]
