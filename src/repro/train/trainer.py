"""Training driver: step loop + checkpoint/resume + data prefetch.

Runs any arch cell (reduced configs on CPU; production shapes on a pod).
Fault tolerance: checkpoints (params, opt_state, step) via the atomic
CheckpointManager; resume picks up from the latest committed step and the
step-indexed data sources regenerate exactly the in-flight batches.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import jax

from ..configs import get_config
from ..launch.mesh import make_host_mesh, use_mesh
from ..launch.steps import build_step
from ..optim import adamw_init
from ..models.params import tree_init
from ..data.pipeline import (TokenSource, GNNFullGraphSource, RecsysSource,
                             SampledGraphSource, Prefetcher)
from .checkpoint import CheckpointManager

__all__ = ["TrainLoop", "make_source"]


def make_source(cfg, shape_name: str, reduced: bool):
    from ..configs import input_specs

    step_kind, avals = input_specs(cfg, shape_name, reduced=reduced)
    if cfg.kind == "lm":
        B, S = avals["tokens"].shape
        return TokenSource(B, S, cfg.vocab)
    if cfg.kind == "recsys":
        B = avals["hist_ids"].shape[0]
        return RecsysSource(cfg, B)
    # gnn
    from ..graph import chung_lu
    from ..configs.shapes import SHAPES_BY_KIND

    batch = avals["batch"]
    N = avals["num_nodes"]
    mode = SHAPES_BY_KIND["gnn"][shape_name]["mode"]
    if mode == "molecule":  # static random disjoint-union batch
        rng = np.random.default_rng(0)
        G = batch["y"].shape[0] if "y" in batch else batch["labels"].shape[0]
        n1 = N // G
        e1 = batch["src"].shape[0] // (2 * G)
        src1 = rng.integers(0, n1, e1)
        dst1 = (src1 + 1 + rng.integers(0, n1 - 1, e1)) % n1
        offs = np.repeat(np.arange(G) * n1, e1)
        s = np.concatenate([np.tile(src1, G) + offs, np.tile(dst1, G) + offs])
        d = np.concatenate([np.tile(dst1, G) + offs, np.tile(src1, G) + offs])
        data = {"src": s.astype(np.int32), "dst": d.astype(np.int32),
                "graph_ids": np.repeat(np.arange(G), n1).astype(np.int32)}
        if "z" in batch:
            data["z"] = rng.integers(1, 90, N).astype(np.int32)
        if "pos" in batch:
            data["pos"] = rng.normal(size=(N, 3)).astype(np.float32)
        if "x" in batch:
            data["x"] = rng.normal(size=batch["x"].shape).astype(np.float32)
        if "y" in batch:
            data["y"] = rng.normal(size=G).astype(np.float32)
        if "labels" in batch:
            data["labels"] = rng.integers(0, cfg.num_classes, G).astype(np.int32)
        return lambda step: data
    if mode == "sampled":
        sh = SHAPES_BY_KIND["gnn"][shape_name]
        B = batch["labels"].shape[0] if "labels" in batch else batch["y"].shape[0]
        fanout = (3, 2) if reduced else sh["fanout"]
        g = chung_lu(max(N * 2, 4096), max(N * 8, 16384), seed=1)
        d_feat = batch["x"].shape[-1] if "x" in batch else 8
        return SampledGraphSource(g, d_feat, cfg.num_classes, B, fanout)
    # full graph: specs reserve one dummy sink node -> real graph has N-1
    e_target = batch["src"].shape[0] // 2
    g = chung_lu(N - 1, e_target, seed=1)
    d_feat = batch["x"].shape[-1] if "x" in batch else 0
    return GNNFullGraphSource(g, d_feat, cfg.num_classes, cfg.arch, pad_nodes=1)


@dataclass
class TrainLoop:
    arch: str
    shape: str = None
    reduced: bool = True
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    log_every: int = 10
    lr: float = 3e-3

    def __post_init__(self):
        from ..optim import AdamWConfig

        # single data shard by choice: TrainLoop drives *reduced* cells whose
        # batch sizes (e.g. 2) need not divide a forced multi-device host
        # (the CI device matrix); production data parallelism goes through
        # launch/steps.py on a real mesh, not this harness.  Pass
        # make_host_mesh(max_data=None) here to span every visible device.
        self.mesh = make_host_mesh(max_data=1)
        cfg = get_config(self.arch)
        if self.reduced:
            cfg = cfg.reduced()
        self.cfg = cfg
        if self.shape is None:
            self.shape = {"lm": "train_4k", "gnn": "full_graph_sm",
                          "recsys": "train_batch"}[cfg.kind]
        self.bundle = build_step(self.arch, self.shape, self.mesh,
                                 reduced=self.reduced,
                                 opt=AdamWConfig(lr=self.lr))
        assert self.bundle.name == "train_step", "TrainLoop needs a train cell"
        self.fn = jax.jit(self.bundle.fn, in_shardings=self.bundle.in_shardings,
                          out_shardings=self.bundle.out_shardings,
                          donate_argnums=self.bundle.donate_argnums)
        self.ckpt = (CheckpointManager(self.checkpoint_dir)
                     if self.checkpoint_dir else None)

    def _init_state(self):
        if self.cfg.kind == "lm":
            from ..models.transformer import lm_param_specs
            specs = lm_param_specs(self.cfg)
        elif self.cfg.kind == "recsys":
            from ..models.recsys import mind_param_specs
            specs = mind_param_specs(self.cfg)
        else:
            from ..models.gnn import gnn_param_specs
            from ..configs import input_specs
            _, av = input_specs(self.cfg, self.shape, reduced=self.reduced)
            d_in = av["batch"]["x"].shape[-1] if "x" in av["batch"] else 0
            specs = gnn_param_specs(self.cfg, d_in)
        params = tree_init(specs, jax.random.PRNGKey(0))
        opt_state = adamw_init(params, self.bundle.static["opt"])
        return params, opt_state

    def run(self, num_steps: int, resume: bool = True) -> dict:
        params, opt_state = self._init_state()
        start = 0
        if self.ckpt and resume:
            try:
                (params, opt_state), start = self.ckpt.restore_latest(
                    (params, opt_state))
                start += 1
            except FileNotFoundError:
                pass
        source = make_source(self.cfg, self.shape, self.reduced)
        prefetch = Prefetcher(source, start_step=start)
        losses = []
        t0 = time.time()
        with use_mesh(self.mesh):
            for i in range(start, start + num_steps):
                step_idx, batch = next(prefetch)
                batch = jax.tree.map(jax.numpy.asarray, batch)
                if self.cfg.kind == "lm":
                    params, opt_state, loss = self.fn(
                        params, opt_state, batch["tokens"], batch["labels"])
                else:
                    params, opt_state, loss = self.fn(params, opt_state, batch)
                losses.append(float(loss))
                if self.log_every and (i + 1) % self.log_every == 0:
                    print(f"step {i + 1}: loss {losses[-1]:.4f}", flush=True)
                if self.ckpt and (i + 1) % self.checkpoint_every == 0:
                    self.ckpt.save(i, (params, opt_state))
        prefetch.close()
        if self.ckpt:
            self.ckpt.save(start + num_steps - 1, (params, opt_state))
            self.ckpt.wait()
        return {"losses": losses, "steps_per_s": len(losses) / (time.time() - t0),
                "final_loss": losses[-1] if losses else float("nan")}
