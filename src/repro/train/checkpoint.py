"""Fault-tolerant checkpointing: atomic step manifests + cross-mesh resharding.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, committed by writing to a
tmp dir and atomically renaming — a crashed save never corrupts the latest
checkpoint.  ``restore`` re-places arrays under any target sharding/mesh
(elastic scaling: N-chip checkpoints restore onto M-chip meshes, since arrays
are saved in logical (global) layout and resharded by jax.device_put).

The decomposition engine checkpoints (core, iteration): by monotone
convergence (Thm 4.1) any intermediate upper-bound state is a valid warm
restart, so crash recovery is exact — no write-ahead log needed.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import numpy as np
import jax

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out, dtypes = {}, {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16, fp8): store raw
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        out[key] = arr
    return out, dtypes, treedef


def save(directory: str, step: int, tree) -> str:
    """Atomic checkpoint write; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        arrays, dtypes, _ = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                     for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, like_tree, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``; reshard if given.

    ``shardings``: optional pytree of NamedSharding matching like_tree —
    enables elastic restore onto a different mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    sh_flat = (jax.tree_util.tree_leaves(shardings) if shardings is not None
               else [None] * len(flat))
    leaves = []
    for (p, like), sh in zip(flat, sh_flat):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        want = manifest["keys"][key]["dtype"]
        if str(arr.dtype) != want:  # stored as raw view (bf16, fp8, ...)
            import ml_dtypes
            arr = arr.view(np.dtype(want))
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Keeps the last `keep` checkpoints; optional async (background) saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        if self._thread is not None:
            self._thread.join()

        def work():
            save(self.directory, step, host_tree)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like_tree, shardings=None):
        self.wait()
        return restore(self.directory, like_tree, shardings=shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
