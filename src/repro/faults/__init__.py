"""Deterministic fault injection + resilience policies (DESIGN.md §17).

The paper's headline — decomposing a 42.6B-edge graph in 4.2 GB — is a
*disk-backed* claim, and disk-backed systems fail in ways clean unit tests
never exercise: torn writes, bit rot, transient ``EIO``, ``ENOSPC``, drives
that acknowledge an fsync they never performed.  This package makes those
failures a first-class, reproducible test input:

* :mod:`plan` — ``FaultPlan``/``FaultRule``: a seeded, scriptable schedule
  of faults keyed by *operation count* (the Nth WAL append, the Kth block
  read), so a test can place a fault at an exact point or run a randomized
  chaos schedule that is bit-reproducible from one integer seed;
* :mod:`fs` — the injection surface: every filesystem touch of the
  durability stack (``stream/wal.py`` appends/fsyncs/rotations, snapshot
  publish/load, ``BlockReader`` block fills) calls a hook here.  With no
  plan installed the hooks are a single ``is None`` check — zero overhead
  on the production path.  Also hosts the power-loss simulator behind the
  lying-fsync mode (un-fsynced bytes and directory entries are lost);
* :mod:`retry` — the hardening the faults exercise: ``RetryPolicy``
  (jittered exponential backoff with a retry budget and deadline) and
  ``CircuitBreaker`` (consecutive-failure trip, used by replica sync to
  fall back to a full bootstrap).

Injected faults surface as :class:`FaultInjected` (an ``IOError`` subclass,
so production retry/except paths treat them exactly like real I/O errors)
and are counted in ``repro_faults_injected_total{op,kind}``.
"""
from .plan import (FAULT_KINDS, FaultInjected, FaultPlan, FaultRule)
from .fs import (active_plan, flip_bit, inject, simulate_power_loss)
from .retry import CircuitBreaker, RetryPolicy

__all__ = [
    "FAULT_KINDS", "FaultInjected", "FaultPlan", "FaultRule",
    "active_plan", "flip_bit", "inject", "simulate_power_loss",
    "CircuitBreaker", "RetryPolicy",
]
