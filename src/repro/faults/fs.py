"""The fault-injection surface + power-loss simulator (DESIGN.md §17).

Production durability code (``stream/wal.py``, ``stream/snapshots``,
``graph/storage.BlockReader``) routes its filesystem side effects through
the hooks here:

* :func:`on_op` — read-ish operations (block fills, tailer polls, snapshot
  loads): may raise a transient :class:`FaultInjected` or inject latency;
* :func:`write` — byte writes (WAL appends): may raise before writing
  (``io_error``), land only a prefix then raise (``torn_write`` /
  ``enospc``), silently flip one bit (``bit_flip``), or delay;
* :func:`fsync` / :func:`fsync_dir` — may lie (return success without
  syncing — and without marking the data durable in the power-loss
  journal) or raise;
* :func:`replace` — atomic renames, journaled so a later simulated power
  loss can undo a rename whose directory entry was never fsynced.

With no plan installed (:data:`_ACTIVE` is ``None``) every hook is a single
attribute check plus the real OS call — the un-faulted hot path pays
nothing measurable.

The **power-loss simulator** backs the lying-fsync test mode: when the
active plan sets ``track_durability``, writes/fsyncs/renames are journaled
and :func:`simulate_power_loss` reverts exactly the state no honored fsync
covered — un-synced file suffixes are truncated away and un-synced
directory entries (renames) are undone.  This is what catches the classic
"fsynced the file but not the directory" bug class.
"""
from __future__ import annotations

import contextlib
import os
import shutil
import time

from .plan import FaultInjected, FaultPlan

__all__ = [
    "inject", "active_plan", "on_op", "write", "fsync", "fsync_dir",
    "replace", "flip_bit", "simulate_power_loss",
]

_ACTIVE: FaultPlan | None = None
_TRACKER: "_DurabilityTracker | None" = None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` as the process-wide fault schedule for the block."""
    global _ACTIVE, _TRACKER
    prev, prev_tracker = _ACTIVE, _TRACKER
    _ACTIVE = plan
    _TRACKER = _DurabilityTracker() if plan.track_durability else None
    try:
        yield plan
    finally:
        _ACTIVE, _TRACKER = prev, prev_tracker


def active_plan() -> FaultPlan | None:
    return _ACTIVE


def _decide(op: str):
    return _ACTIVE.decide(op) if _ACTIVE is not None else None


# ------------------------------------------------------------------- hooks
def on_op(op: str) -> None:
    """Hook for read-ish operations: may raise transiently or add latency."""
    d = _decide(op)
    if d is None:
        return
    kind, arg, index = d
    if kind == "latency":
        time.sleep(arg)
    elif kind in ("io_error", "enospc"):
        raise FaultInjected(op, kind, index)
    # write-only kinds scheduled against a read op degrade to io_error so a
    # chaos schedule can use one rate table across mixed op patterns
    elif kind in ("torn_write", "bit_flip"):
        raise FaultInjected(op, "io_error", index)


def write(f, op: str, data: bytes, path: str | None = None) -> None:
    """Write ``data`` to file object ``f``, subject to the active plan.

    ``io_error`` raises before anything lands; ``torn_write``/``enospc``
    land ``arg``-fraction of the bytes then raise; ``bit_flip`` lands all
    bytes with one deterministically chosen bit inverted (silent — only a
    checksum can catch it); ``latency`` sleeps first.  All landed bytes are
    journaled as *not yet durable* when power-loss tracking is armed.
    """
    d = _decide(op)
    if d is None:
        _note_write(f, path, data)
        f.write(data)
        return
    kind, arg, index = d
    if kind == "io_error":
        raise FaultInjected(op, kind, index)
    if kind == "latency":
        time.sleep(arg)
    elif kind in ("torn_write", "enospc"):
        torn = data[: max(0, int(len(data) * arg))]
        _note_write(f, path, torn)
        f.write(torn)
        f.flush()
        raise FaultInjected(op, kind, index)
    elif kind == "bit_flip" and len(data) > 1:
        # never flip the trailing record delimiter: bit rot inside a record
        # is the case checksums exist for (a lost delimiter is a torn tail,
        # which framing already handles)
        pos = _ACTIVE._rng.randrange((len(data) - 1) * 8)
        b = bytearray(data)
        b[pos // 8] ^= 1 << (pos % 8)
        data = bytes(b)
    _note_write(f, path, data)
    f.write(data)


def fsync(f, op: str, path: str | None = None) -> bool:
    """fsync ``f`` unless the plan says the drive lies.  Returns True when
    the sync actually happened (and marks the file durable in the
    power-loss journal)."""
    d = _decide(op)
    if d is not None:
        kind, _arg, index = d
        if kind == "lying_fsync":
            return False  # reported success, nothing durable
        if kind in ("io_error", "enospc"):
            raise FaultInjected(op, kind, index)
    os.fsync(f.fileno())
    if _TRACKER is not None and path is not None:
        _TRACKER.mark_file_durable(path)
    return True


def fsync_dir(path: str, op: str = "fsync_dir") -> bool:
    """fsync a *directory* so renamed/created entries survive power loss.

    The satellite bugfix: ``os.replace`` makes a rename atomic but not
    durable — the new directory entry lives in the page cache until the
    directory inode is synced.  No-op (returns False) on platforms that
    cannot open directories; honors lying-fsync faults.
    """
    d = _decide(op)
    if d is not None:
        kind, _arg, index = d
        if kind == "lying_fsync":
            return False
        if kind in ("io_error", "enospc"):
            raise FaultInjected(op, kind, index)
    try:
        fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    except OSError:
        return False  # platform without directory fds: nothing to do
    try:
        os.fsync(fd)
    except OSError:
        return False
    finally:
        os.close(fd)
    if _TRACKER is not None:
        _TRACKER.mark_dir_durable(path)
    return True


def replace(src: str, dst: str, op: str = "replace") -> None:
    """``os.replace`` with fault + durability-journal hooks."""
    d = _decide(op)
    if d is not None:
        kind, arg, index = d
        if kind in ("io_error", "enospc"):
            raise FaultInjected(op, kind, index)
        if kind == "latency":
            time.sleep(arg)
    if _TRACKER is not None:
        _TRACKER.note_replace(src, dst)
    os.replace(src, dst)


def _note_write(f, path: str | None, data: bytes) -> None:
    if _TRACKER is not None and path is not None and data:
        _TRACKER.note_write(path, f)


# ------------------------------------------------- power-loss simulation
class _DurabilityTracker:
    """Journal of what would survive a power cut right now.

    Files: the durable prefix length (baseline = size when first seen;
    advanced only by an *honored* fsync).  Directories: a stack of undo
    actions for renames whose directory entry was never dir-fsynced.
    """

    def __init__(self):
        self.file_durable: dict[str, int] = {}
        self.dir_pending: dict[str, list] = {}

    # -- files -------------------------------------------------------------
    def note_write(self, path: str, f) -> None:
        path = os.path.abspath(path)
        if path not in self.file_durable:
            try:
                f.flush()
            except (OSError, ValueError):
                pass
            size = os.path.getsize(path) if os.path.exists(path) else 0
            self.file_durable[path] = size

    def mark_file_durable(self, path: str) -> None:
        path = os.path.abspath(path)
        if os.path.exists(path):
            self.file_durable[path] = os.path.getsize(path)

    # -- directory entries ---------------------------------------------------
    def note_replace(self, src: str, dst: str) -> None:
        dst = os.path.abspath(dst)
        parent = os.path.dirname(dst)
        shadow = None
        if os.path.exists(dst):  # preserve the pre-rename target for undo
            shadow = dst + ".preloss_shadow"
            if os.path.isdir(dst):
                if os.path.exists(shadow):
                    shutil.rmtree(shadow)
                shutil.copytree(dst, shadow)
            else:
                shutil.copy2(dst, shadow)
        self.dir_pending.setdefault(parent, []).append((dst, shadow))
        # the rename rewrites dst wholesale: byte-level tracking is stale
        self.file_durable.pop(dst, None)

    def mark_dir_durable(self, path: str) -> None:
        for dst, shadow in self.dir_pending.pop(os.path.abspath(path), []):
            if shadow and os.path.exists(shadow):
                (shutil.rmtree if os.path.isdir(shadow) else os.remove)(shadow)

    # -- the cut -----------------------------------------------------------
    def power_loss(self) -> None:
        for path, durable in self.file_durable.items():
            if os.path.exists(path) and os.path.getsize(path) > durable:
                with open(path, "rb+") as f:
                    f.truncate(durable)
        for undos in self.dir_pending.values():
            for dst, shadow in reversed(undos):
                if os.path.exists(dst):  # the entry never hit the disk
                    (shutil.rmtree if os.path.isdir(dst) else os.remove)(dst)
                if shadow and os.path.exists(shadow):
                    os.replace(shadow, dst)
        self.file_durable.clear()
        self.dir_pending.clear()


def simulate_power_loss() -> None:
    """Revert every un-fsynced effect journaled since ``inject()`` armed the
    tracker (requires a plan with ``track_durability=True``)."""
    if _TRACKER is None:
        raise RuntimeError(
            "power-loss simulation needs an active FaultPlan with "
            "track_durability=True")
    _TRACKER.power_loss()


# ----------------------------------------------------------- test utility
def flip_bit(path: str, byte_index: int, bit: int = 0) -> None:
    """Flip one bit of a file in place — at-rest bit rot for tests.

    Negative ``byte_index`` counts from the end of the file.
    """
    with open(path, "rb+") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if byte_index < 0:
            byte_index += size
        if not (0 <= byte_index < size):
            raise ValueError(f"byte {byte_index} outside file of {size} bytes")
        f.seek(byte_index)
        b = f.read(1)[0] ^ (1 << (bit % 8))
        f.seek(byte_index)
        f.write(bytes([b]))
