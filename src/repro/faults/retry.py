"""Retry + circuit-breaker policies for the durability stack (DESIGN.md §17).

:class:`RetryPolicy` wraps a callable with jittered exponential backoff
under three independent limits — attempt budget, total-delay deadline, and
which exception types count as transient.  :class:`CircuitBreaker` counts
consecutive failures and trips after a threshold; replica sync uses it to
stop banging on a wedged WAL and fall back to a full snapshot bootstrap.

Both are deterministic test citizens: the jitter RNG is seeded and the
sleep function is injectable, so a chaos run with a fixed seed replays the
exact same backoff schedule.
"""
from __future__ import annotations

import random
import time

from ..obs import metrics as _metrics

__all__ = ["RetryPolicy", "CircuitBreaker"]

_RETRIES = _metrics.counter(
    "repro_retries_total",
    "I/O retries performed by RetryPolicy, by operation")
_EXHAUSTED = _metrics.counter(
    "repro_retries_exhausted_total",
    "RetryPolicy give-ups (budget or deadline exhausted), by operation")


class RetryPolicy:
    """Jittered exponential backoff with an attempt budget and a deadline.

    ``retries`` is the number of *re*-attempts after the first call (so
    ``retries=3`` means up to 4 calls).  Delay before retry ``k`` (1-based)
    is ``base_delay * 2**(k-1)`` capped at ``max_delay``, scaled by a
    uniform jitter in ``[1-jitter, 1]``.  ``deadline`` caps the *summed*
    sleep time; once it would be exceeded the policy gives up early.
    """

    def __init__(self, retries: int = 3, *, base_delay: float = 0.01,
                 max_delay: float = 1.0, deadline: float | None = None,
                 jitter: float = 0.5, seed: int = 0, sleep=time.sleep):
        if retries < 0:
            raise ValueError("retries must be >= 0")
        self.retries = int(retries)
        self.base_delay = float(base_delay)
        self.max_delay = float(max_delay)
        self.deadline = deadline
        self.jitter = min(max(float(jitter), 0.0), 1.0)
        self._rng = random.Random(seed)
        self._sleep = sleep

    def delays(self):
        """Yield the backoff delays this policy would sleep, in order."""
        total = 0.0
        for k in range(self.retries):
            d = min(self.base_delay * (2.0 ** k), self.max_delay)
            d *= 1.0 - self.jitter * self._rng.random()
            if self.deadline is not None and total + d > self.deadline:
                return
            total += d
            yield d

    def call(self, fn, *args, op: str = "io", retry_on=(OSError,), **kw):
        """Invoke ``fn(*args, **kw)``, retrying on ``retry_on`` exceptions.

        Re-raises the last exception once the budget or deadline is spent;
        each retry bumps ``repro_retries_total{op}`` and each give-up bumps
        ``repro_retries_exhausted_total{op}``.
        """
        delays = self.delays()
        while True:
            try:
                return fn(*args, **kw)
            except retry_on:
                delay = next(delays, None)
                if delay is None:
                    _EXHAUSTED.labels(op=op).inc()
                    raise
                _RETRIES.labels(op=op).inc()
                self._sleep(delay)


class CircuitBreaker:
    """Trip after ``trip_after`` consecutive failures; reset on success.

    The breaker only *reports* its state — the caller decides what the trip
    means (for :class:`~repro.stream.replica.CoreReplica` it means: stop
    incremental tailing, do a full snapshot bootstrap).
    """

    def __init__(self, trip_after: int = 3):
        if trip_after < 1:
            raise ValueError("trip_after must be >= 1")
        self.trip_after = int(trip_after)
        self.consecutive_failures = 0
        self.trips = 0

    @property
    def tripped(self) -> bool:
        return self.consecutive_failures >= self.trip_after

    def record_failure(self) -> bool:
        """Count a failure; returns True when this one trips the breaker."""
        self.consecutive_failures += 1
        if self.consecutive_failures == self.trip_after:
            self.trips += 1
            return True
        return False

    def record_success(self) -> None:
        self.consecutive_failures = 0

    def reset(self) -> None:
        self.consecutive_failures = 0
