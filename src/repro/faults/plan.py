"""Seeded, scriptable fault schedules (DESIGN.md §17).

A :class:`FaultPlan` decides, per instrumented operation, whether to inject
a fault and which kind.  Decisions come from two sources, checked in order:

* **scripted rules** (:class:`FaultRule`): match an operation name (fnmatch
  pattern) at an exact per-operation count (``nth``), on a period
  (``every``), or on every call — this is how a test places a torn write at
  exactly the 3rd WAL append;
* **random rates**: ``{op_pattern: {kind: probability}}`` drawn from one
  ``random.Random(seed)`` stream — the chaos-soak schedule.  Because the
  instrumented workloads are themselves deterministic, the whole faulted
  run is bit-reproducible from the seed.

Every injection increments ``repro_faults_injected_total{op,kind}`` and the
plan's own ``injected`` tally, so a test can assert that each scheduled
fault actually fired.
"""
from __future__ import annotations

import errno
import random
from dataclasses import dataclass
from fnmatch import fnmatch

from ..obs import metrics as _metrics

__all__ = ["FAULT_KINDS", "FaultInjected", "FaultRule", "FaultPlan"]

#: every fault kind the injection surface understands.
#:   io_error    -- transient EIO: the op raises, nothing happened on disk
#:   enospc      -- out of space: a *prefix* of the data lands, then ENOSPC
#:   torn_write  -- short write: a prefix of the data lands, then EIO
#:   bit_flip    -- silent single-bit corruption of the written payload
#:   lying_fsync -- fsync returns success without making anything durable
#:   latency     -- the op succeeds after an injected delay
FAULT_KINDS = (
    "io_error", "enospc", "torn_write", "bit_flip", "lying_fsync", "latency",
)

_INJECTED = _metrics.counter(
    "repro_faults_injected_total",
    "Faults injected by the active FaultPlan, by operation and kind")


class FaultInjected(IOError):
    """A deliberately injected I/O failure (transient by construction).

    Subclasses ``IOError`` so production code handles it exactly like a real
    disk error; ``.op``/``.kind``/``.index`` identify the injection site for
    test assertions.
    """

    def __init__(self, op: str, kind: str, index: int):
        ncode = errno.ENOSPC if kind == "enospc" else errno.EIO
        super().__init__(ncode, f"injected {kind} at {op}#{index}")
        self.op = op
        self.kind = kind
        self.index = index


@dataclass(frozen=True)
class FaultRule:
    """One scripted fault: ``kind`` fires when ``op`` matches the pattern.

    ``nth`` (1-based) fires on exactly the Nth matching operation;
    ``every`` fires on every ``every``-th; with neither, every matching
    operation faults.  ``arg`` is kind-specific: the surviving fraction for
    torn/ENOSPC writes, the delay in seconds for latency, ignored otherwise.
    """

    op: str
    kind: str
    nth: int | None = None
    every: int | None = None
    arg: float = 0.5

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    def fires_at(self, count: int) -> bool:
        if self.nth is not None:
            return count == self.nth
        if self.every is not None:
            return count % self.every == 0
        return True


class FaultPlan:
    """A deterministic schedule of faults over instrumented operations.

    ``rules`` are scripted (checked first, in order); ``rates`` add a
    seeded random layer: ``{op_pattern: {kind: probability}}``.  One
    operation suffers at most one fault per call.

    ``track_durability=True`` additionally arms the power-loss simulator in
    :mod:`repro.faults.fs`: writes, fsyncs and renames are journaled so a
    test can call :func:`repro.faults.fs.simulate_power_loss` and observe
    exactly the un-fsynced state vanish (the lying-fsync test mode).
    """

    def __init__(self, rules=(), *, seed: int = 0, rates=None,
                 track_durability: bool = False):
        self.rules = tuple(rules)
        self.rates = {str(k): dict(v) for k, v in (rates or {}).items()}
        self.seed = int(seed)
        self.track_durability = bool(track_durability)
        self._rng = random.Random(self.seed)
        self.op_counts: dict[str, int] = {}  # ops seen, faulted or not
        self.injected: dict[tuple[str, str], int] = {}  # (op, kind) -> n
        self.log: list[tuple[str, str, int]] = []  # (op, kind, op_index)

    @classmethod
    def chaos(cls, seed: int, rates, **kw) -> "FaultPlan":
        """A purely random schedule — the chaos-soak constructor."""
        return cls((), seed=seed, rates=rates, **kw)

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    # ------------------------------------------------------------- decision
    def decide(self, op: str):
        """Return ``(kind, arg, op_index)`` to inject, or ``None``.

        Counts every call per exact op name (the Nth-operation clock), then
        consults scripted rules and the random rates.  The RNG is consumed
        *once per matching rate entry* in sorted order, so the draw sequence
        — hence the whole schedule — is a pure function of the seed and the
        operation stream.
        """
        count = self.op_counts.get(op, 0) + 1
        self.op_counts[op] = count
        for rule in self.rules:
            if fnmatch(op, rule.op) and rule.fires_at(count):
                return self._record(op, rule.kind, rule.arg, count)
        for pattern in sorted(self.rates):
            if not fnmatch(op, pattern):
                continue
            for kind in sorted(self.rates[pattern]):
                prob = self.rates[pattern][kind]
                if self._rng.random() < prob:
                    arg = 0.001 if kind == "latency" else 0.5
                    return self._record(op, kind, arg, count)
        return None

    def _record(self, op: str, kind: str, arg: float, count: int):
        key = (op, kind)
        self.injected[key] = self.injected.get(key, 0) + 1
        self.log.append((op, kind, count))
        _INJECTED.labels(op=op, kind=kind).inc()
        return kind, arg, count
