"""Shared result schema for benchmark scripts.

All three bench scripts (``bench_backends``, ``bench_stream``,
``bench_outofcore``) used to invent their own JSON shapes for the same
quantities.  They now embed one common block, sourced from the metrics
registry via snapshot/delta, so downstream tooling (CI summaries, the
roofline, trajectory checks) can read any bench output the same way::

    {
      "schema": "repro-obs-bench-v1",
      "bench": "<script name>",
      "wall_seconds": ...,
      "counters": {"repro_io_edge_block_reads_total": ..., ...},
      "derived": {"io_bytes_per_s": ..., ...}
    }

``counters`` is the flat registry delta for the measured region (label
suffixes preserved); ``derived`` holds a few convenience rates.
"""
from __future__ import annotations

from typing import Mapping, Optional

from .metrics import sum_by_name

__all__ = ["OBS_BENCH_SCHEMA", "shared_result"]

OBS_BENCH_SCHEMA = "repro-obs-bench-v1"


def shared_result(bench: str, wall_seconds: Optional[float],
                  counters: Mapping[str, float],
                  extra: Optional[dict] = None) -> dict:
    """Build the common bench block from a registry delta."""
    kept = {k: v for k, v in counters.items()
            if k.startswith("repro_") and v != 0}
    out: dict = {
        "schema": OBS_BENCH_SCHEMA,
        "bench": bench,
        "wall_seconds": wall_seconds,
        "counters": kept,
        "derived": {},
    }
    if wall_seconds and wall_seconds > 0:
        io_bytes = sum_by_name(kept, "repro_io_bytes_read_total")
        if io_bytes:
            out["derived"]["io_bytes_per_s"] = io_bytes / wall_seconds
        passes = sum_by_name(kept, "repro_engine_passes_total")
        if passes:
            out["derived"]["passes_per_s"] = passes / wall_seconds
    if extra:
        out["derived"].update(extra)
    return out
