"""Unified telemetry for the repro: metrics registry + Chrome-trace spans.

See DESIGN.md §14.  Always-on process-local counters/gauges/histograms with a
``REPRO_OBS=0`` kill switch, plus an opt-in span timeline loadable in
Perfetto.  Zero third-party dependencies; safe to import from any layer.
"""
from .metrics import (  # noqa: F401
    OBS_ENV_VAR,
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    gauge,
    get_registry,
    histogram,
    obs_enabled,
    sum_by_name,
)
from .trace import (  # noqa: F401
    TRACE_ENV_VAR,
    Span,
    TraceCollector,
    clear_trace,
    get_collector,
    instant,
    save_trace,
    span,
    start_trace,
    stop_trace,
    tracing_active,
)
from .bench import OBS_BENCH_SCHEMA, shared_result  # noqa: F401

__all__ = [
    "OBS_ENV_VAR",
    "TRACE_ENV_VAR",
    "OBS_BENCH_SCHEMA",
    "DEFAULT_TIME_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "TraceCollector",
    "counter",
    "gauge",
    "histogram",
    "get_registry",
    "get_collector",
    "obs_enabled",
    "sum_by_name",
    "shared_result",
    "span",
    "instant",
    "start_trace",
    "stop_trace",
    "save_trace",
    "clear_trace",
    "tracing_active",
]
