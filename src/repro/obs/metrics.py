"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Zero-dependency (stdlib only) and cheap enough to be always-on: every mutator
is a dict lookup, an env-var check, and a float add.  The global kill switch is
the ``REPRO_OBS`` environment variable — set ``REPRO_OBS=0`` and every
``inc``/``set``/``observe`` becomes a no-op while the underlying algorithm
counters (``BlockReader.reads``, ``DecompResult`` fields, …) keep working
exactly as before.  The switch is read per call so tests can flip it with
``monkeypatch.setenv`` mid-process.

Naming scheme (see DESIGN.md §14):

* ``repro_<subsystem>_<noun>_<unit>`` — e.g. ``repro_io_edge_block_reads_total``,
  ``repro_service_ingest_seconds``;
* counters end in ``_total``, histograms in a unit (``_seconds``), gauges are
  bare nouns (``repro_service_epoch``);
* labels are few and low-cardinality: ``algorithm``, ``backend``, ``schedule``,
  ``kind``, ``path``.

Reconciliation contract: the I/O counters are incremented at the *same source
lines* as the paper-accounting fields they mirror, so for any single
``decompose()`` call the registry delta equals the ``DecompResult`` fields
exactly (enforced by ``tests/test_obs.py`` on the Fig. 2/4/5 pinned traces).
"""
from __future__ import annotations

import bisect
import math
import os
from typing import Dict, Iterable, Mapping, Optional, Tuple

__all__ = [
    "OBS_ENV_VAR",
    "obs_enabled",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "counter",
    "gauge",
    "histogram",
    "sum_by_name",
    "DEFAULT_TIME_BUCKETS",
    "DEFAULT_COUNT_BUCKETS",
]

OBS_ENV_VAR = "REPRO_OBS"

#: log-ish spaced latency buckets, 100µs .. 10s (upper bounds, seconds).
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
    0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

#: log-ish spaced buckets for small non-negative counts (replica lag in
#: epochs, queue depths, ...).  0 gets its own bucket so "fully caught up"
#: is distinguishable from "1 epoch behind" in the exposition.
DEFAULT_COUNT_BUCKETS: Tuple[float, ...] = (
    0.0, 1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0,
)

_LabelKey = Tuple[Tuple[str, str], ...]


def obs_enabled() -> bool:
    """True unless the process was told ``REPRO_OBS=0``.

    Read from the environment on every call (a dict get, ~100ns) so the
    switch works mid-process without re-importing anything; the parse rule
    is declared with the rest of the knobs in :mod:`repro.runtime` (this
    inline read keeps the per-``inc`` hot path one dict get).
    """
    return os.environ.get(OBS_ENV_VAR, "1") != "0"


def _label_key(labels: Mapping[str, str]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _format_labels(key: _LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _CounterSeries:
    """One labeled time series of a counter family."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if obs_enabled():
            self.value += amount


class _GaugeSeries:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        if obs_enabled():
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if obs_enabled():
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class _HistogramSeries:
    """Fixed-bucket histogram series (cumulative counts in exposition only)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...]) -> None:
        self.buckets = buckets  # sorted upper bounds; +Inf bucket is implicit
        self.counts = [0] * (len(buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if not obs_enabled():
            return
        value = float(value)
        self.counts[bisect.bisect_left(self.buckets, value)] += 1
        self.sum += value
        self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
                frac = (target - seen) / c
                return lo + (hi - lo) * min(1.0, max(0.0, frac))
            seen += c
        return self.buckets[-1]


class _MetricFamily:
    kind = "untyped"

    def __init__(self, name: str, help: str) -> None:
        self.name = name
        self.help = help
        self._series: Dict[_LabelKey, object] = {}

    def _make_series(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def labels(self, **labels: str):
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._make_series()
            self._series[key] = s
        return s

    @property
    def _default(self):
        return self.labels()


class Counter(_MetricFamily):
    kind = "counter"

    def _make_series(self) -> _CounterSeries:
        return _CounterSeries()

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    @property
    def value(self) -> float:
        return sum(s.value for s in self._series.values())


class Gauge(_MetricFamily):
    kind = "gauge"

    def _make_series(self) -> _GaugeSeries:
        return _GaugeSeries()

    def set(self, value: float) -> None:
        self._default.set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default.dec(amount)

    @property
    def value(self) -> float:
        return self._default.value


class Histogram(_MetricFamily):
    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Iterable[float] = DEFAULT_TIME_BUCKETS) -> None:
        super().__init__(name, help)
        bks = tuple(sorted(float(b) for b in buckets))
        if not bks:
            raise ValueError("histogram needs at least one bucket bound")
        self.bucket_bounds = bks

    def _make_series(self) -> _HistogramSeries:
        return _HistogramSeries(self.bucket_bounds)

    def observe(self, value: float) -> None:
        self._default.observe(value)

    def quantile(self, q: float) -> float:
        return self._default.quantile(q)

    @property
    def count(self) -> int:
        return sum(s.count for s in self._series.values())

    @property
    def sum(self) -> float:
        return sum(s.sum for s in self._series.values())


class MetricsRegistry:
    """Holds metric families by name; families are create-once, get-forever.

    ``snapshot()``/``delta()`` give the cheap "what did *this* run cost"
    discipline used by the benches and the reconciliation tests:

        snap = reg.snapshot()
        ...work...
        d = reg.delta(snap)          # flat {sample_name: numeric delta}
    """

    def __init__(self) -> None:
        self._families: Dict[str, _MetricFamily] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _MetricFamily:
        fam = self._families.get(name)
        if fam is None:
            fam = cls(name, help, **kw)
            self._families[name] = fam
        elif not isinstance(fam, cls):
            raise TypeError(
                f"metric {name!r} already registered as {fam.kind}, "
                f"requested {cls.kind}"
            )
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_MetricFamily]:
        return self._families.get(name)

    # -- flat sample view ---------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{labels}: value}`` of all monotonic samples.

        Counters yield their value; histograms yield ``_sum`` and ``_count``
        samples; gauges are point-in-time and excluded (deltas of a gauge are
        meaningless).
        """
        out: Dict[str, float] = {}
        for fam in self._families.values():
            for key, series in fam._series.items():
                lbl = _format_labels(key)
                if fam.kind == "counter":
                    out[f"{fam.name}{lbl}"] = series.value
                elif fam.kind == "histogram":
                    out[f"{fam.name}_sum{lbl}"] = series.sum
                    out[f"{fam.name}_count{lbl}"] = float(series.count)
        return out

    def delta(self, since: Mapping[str, float]) -> Dict[str, float]:
        """Current snapshot minus ``since`` (samples born later count fully)."""
        now = self.snapshot()
        return {k: v - since.get(k, 0.0) for k, v in now.items()}

    # -- exposition ---------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-friendly dump of every family and series."""
        out: dict = {}
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            series = []
            for key, s in sorted(fam._series.items()):
                entry: dict = {"labels": dict(key)}
                if fam.kind == "histogram":
                    entry["sum"] = s.sum
                    entry["count"] = s.count
                    entry["buckets"] = [
                        [b, c] for b, c in zip(list(s.buckets) + ["+Inf"], s.counts)
                    ]
                else:
                    entry["value"] = s.value
                series.append(entry)
            out[fam.name] = {"type": fam.kind, "help": fam.help, "series": series}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, s in sorted(fam._series.items()):
                lbl = _format_labels(key)
                if fam.kind == "histogram":
                    cum = 0
                    for b, c in zip(s.buckets, s.counts):
                        cum += c
                        le = _format_labels(key + (("le", _fmt_float(b)),))
                        lines.append(f"{fam.name}_bucket{le} {cum}")
                    cum += s.counts[-1]
                    le = _format_labels(key + (("le", "+Inf"),))
                    lines.append(f"{fam.name}_bucket{le} {cum}")
                    lines.append(f"{fam.name}_sum{lbl} {_fmt_float(s.sum)}")
                    lines.append(f"{fam.name}_count{lbl} {s.count}")
                else:
                    lines.append(f"{fam.name}{lbl} {_fmt_float(s.value)}")
        return "\n".join(lines) + "\n"


def _fmt_float(v: float) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15 and math.isfinite(v):
        return str(int(v))
    return repr(float(v))


def sum_by_name(delta: Mapping[str, float], name: str) -> float:
    """Sum a flat snapshot/delta across all label series of one family.

    Matches the bare sample name exactly or with a ``{...}`` label suffix, so
    ``sum_by_name(d, "repro_engine_passes_total")`` aggregates every
    algorithm/backend combination touched between the two snapshots.
    """
    pref = name + "{"
    return sum(v for k, v in delta.items() if k == name or k.startswith(pref))


_default_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry all repro subsystems write to."""
    return _default_registry


def counter(name: str, help: str = "") -> Counter:
    return _default_registry.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _default_registry.gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Iterable[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
    return _default_registry.histogram(name, help, buckets=buckets)
