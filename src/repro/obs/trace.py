"""Chrome-trace-format span timeline for supersteps, WAL and service events.

Emits the JSON "trace event format" consumed by ``chrome://tracing`` and
https://ui.perfetto.dev — complete events (``ph: "X"``) for timed spans and
instant events (``ph: "i"``) for replayed per-pass markers.  Timestamps are
microseconds relative to ``start_trace()``.

Design constraints, in order:

1. **Never perturb the computation.**  Spans only read values the host already
   has (frontier sizes, pinned per-chunk masks, planner charges); nothing is
   forced off-device for tracing.  The trace-parity test in
   ``tests/test_obs.py`` asserts instrumented runs are bit-identical.
2. **Zero cost when off.**  Tracing is opt-in: ``span()`` returns a shared
   no-op singleton unless a collector was started (``start_trace()`` or the
   ``REPRO_TRACE`` env var) *and* ``REPRO_OBS`` is not ``0``.  The fast path
   is one attribute read and one env check.

``REPRO_TRACE`` values: unset/``0`` — off; ``1`` — collect (caller saves);
any other string — collect and atexit-save to that path.
"""
from __future__ import annotations

import atexit
import json
import os
import time
from typing import List, Optional

from .metrics import obs_enabled

__all__ = [
    "TRACE_ENV_VAR",
    "Span",
    "TraceCollector",
    "get_collector",
    "start_trace",
    "stop_trace",
    "save_trace",
    "clear_trace",
    "tracing_active",
    "span",
    "instant",
]

TRACE_ENV_VAR = "REPRO_TRACE"


class _NullSpan:
    """Shared do-nothing span handed out when tracing is off."""

    __slots__ = ()
    active = False

    def set(self, **args) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Span:
    """A timed complete event; use as a context manager.

    ``set(**args)`` attaches extra args visible in the Perfetto side panel
    (frontier sizes, block activity, probe counts, …).
    """

    __slots__ = ("_collector", "name", "cat", "args", "_t0")
    active = True

    def __init__(self, collector: "TraceCollector", name: str, cat: str, args: dict):
        self._collector = collector
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def set(self, **args) -> "Span":
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._collector._emit_complete(self)


class TraceCollector:
    """Accumulates trace events; one per process is plenty."""

    def __init__(self) -> None:
        self.events: List[dict] = []
        self.active = False
        self._epoch = 0.0
        self._pid = os.getpid()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if not self.active:
            self.active = True
            self._epoch = time.perf_counter()

    def stop(self) -> None:
        self.active = False

    def clear(self) -> None:
        self.events = []
        self._epoch = time.perf_counter()

    def _enabled(self) -> bool:
        return self.active and obs_enabled()

    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    # -- event emission -----------------------------------------------------
    def span(self, name: str, cat: str = "repro", **args):
        if not self._enabled():
            return _NULL_SPAN
        return Span(self, name, cat, args)

    def _emit_complete(self, sp: Span) -> None:
        if not self._enabled():
            return
        now = time.perf_counter()
        self.events.append({
            "name": sp.name,
            "cat": sp.cat,
            "ph": "X",
            "ts": self._us(sp._t0),
            "dur": (now - sp._t0) * 1e6,
            "pid": self._pid,
            "tid": 0,
            "args": sp.args,
        })

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        if not self._enabled():
            return
        self.events.append({
            "name": name,
            "cat": cat,
            "ph": "i",
            "ts": self._us(time.perf_counter()),
            "s": "t",
            "pid": self._pid,
            "tid": 0,
            "args": args,
        })

    # -- output -------------------------------------------------------------
    def to_chrome(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_chrome(), f)
        return path


_collector = TraceCollector()


def get_collector() -> TraceCollector:
    return _collector


def start_trace() -> None:
    """Begin collecting trace events (idempotent)."""
    _collector.start()


def stop_trace() -> None:
    _collector.stop()


def clear_trace() -> None:
    _collector.clear()


def save_trace(path: str) -> str:
    """Write the collected timeline as Chrome-trace JSON and return the path."""
    return _collector.save(path)


def tracing_active() -> bool:
    return _collector._enabled()


def span(name: str, cat: str = "repro", **args):
    """Open a span against the process collector (no-op singleton when off)."""
    return _collector.span(name, cat, **args)


def instant(name: str, cat: str = "repro", **args) -> None:
    _collector.instant(name, cat, **args)


def _init_from_env() -> None:
    val = os.environ.get(TRACE_ENV_VAR, "")
    if not val or val == "0":
        return
    start_trace()
    if val != "1":
        atexit.register(save_trace, val)


_init_from_env()
